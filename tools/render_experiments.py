"""Render the §Dry-run and §Roofline markdown tables from the dry-run
JSON artifacts into EXPERIMENTS.generated.md fragments (pasted into
EXPERIMENTS.md by the build notes)."""
import glob
import json
import os
import sys

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "dryrun")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.ici import pod_collective_model  # noqa: E402


def cells(mesh):
    out = []
    for p in sorted(glob.glob(os.path.join(DRY, mesh, "*", "*.json"))):
        rec = json.load(open(p))
        out.append(rec)
    return out


def fmt(x, n=4):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x:.1e}"
    return f"{x:.{n}f}"


def main():
    lines = []
    lines.append("### Single-pod (16x16 = 256 chips) baseline roofline\n")
    lines.append("| arch | shape | compute (s) | memory (s) | "
                 "collective (s) | dominant | roofline frac | "
                 "useful FLOPs | ICI cong. | note |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    base = [r for r in cells("single") if not r.get("tag")]
    tags = [r for r in cells("single") if r.get("tag")]
    for r in sorted(base, key=lambda r: (r["arch"], r["shape"])):
        t = r["roofline"]
        ici = pod_collective_model(r["collectives"]["by_kind_traffic"],
                                   r["mesh_axes"])
        note = ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(t['compute_s'])} | "
            f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
            f"{t['dominant'].replace('_s', '')} | "
            f"{t['roofline_fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{ici['congestion_factor']:.2f} | {note} |")
    lines.append("\n### Multi-pod (2x16x16 = 512 chips) — pod axis "
                 "shards\n")
    lines.append("| arch | shape | compute (s) | memory (s) | "
                 "collective (s) | dominant |")
    lines.append("|---|---|---|---|---|---|")
    for r in sorted(cells("multi"), key=lambda r: (r["arch"],
                                                   r["shape"])):
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(t['compute_s'])} | "
            f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
            f"{t['dominant'].replace('_s', '')} |")
    lines.append("\n### Tagged perf variants (single-pod)\n")
    lines.append("| arch | shape | tag | compute (s) | memory (s) | "
                 "collective (s) | useful |")
    lines.append("|---|---|---|---|---|---|---|")
    for r in sorted(tags, key=lambda r: (r["arch"], r["shape"],
                                         r["tag"])):
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['tag']} | "
            f"{fmt(t['compute_s'])} | {fmt(t['memory_s'])} | "
            f"{fmt(t['collective_s'])} | "
            f"{r['useful_flops_ratio']:.2f} |")
    out = "\n".join(lines)
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "roofline_tables.md")
    with open(path, "w") as f:
        f.write(out)
    print(out[:2000])
    print(f"... written to {path}")


if __name__ == "__main__":
    main()
