"""End-to-end training driver: train a ~smoke-scale LM for a few hundred
steps on CPU with the full production substrate — synthetic data pipeline,
AdamW + cosine schedule, checkpointing, fault-tolerant supervisor with an
injected mid-run failure, and straggler monitoring.

    PYTHONPATH=src python examples/train_tinylm.py [--steps 200]
"""
import argparse
import tempfile


import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.configs import get_smoke
from repro.data import SyntheticTokens
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.runtime import StragglerMonitor, Supervisor
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", type=str, default="tinyllama_1_1b")
    args = ap.parse_args()

    cfg = get_smoke(args.arch).replace(ce_seq_chunk=32, moe_groups=2)
    model = build_model(cfg)
    opt = adamw(cosine_schedule(3e-3, 20, args.steps))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"{cfg.name}: {n_params / 1e6:.2f}M params (smoke config)")

    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=64,
                         global_batch=8, seed=0)
    step_fn = jax.jit(make_train_step(model, opt, microbatches=2))

    fail_once = {args.steps // 2}

    def injector(step):
        if step in fail_once:
            fail_once.discard(step)
            return RuntimeError("injected failure (fault-tolerance demo)")
        return None

    with tempfile.TemporaryDirectory() as ckpt_dir:
        sup = Supervisor(
            step_fn=step_fn,
            batch_fn=lambda s: {k: jnp.asarray(v)
                                for k, v in ds.batch(s).items()},
            ckpt=CheckpointManager(ckpt_dir, keep=2),
            ckpt_every=25,
            monitor=StragglerMonitor(n_hosts=4),
            failure_injector=injector)
        state = sup.run(state, start_step=0, num_steps=args.steps)

    losses = [h["metrics"]["loss"] for h in sup.history
              if h["event"] == "step"]
    restarts = sum(1 for h in sup.history if h["event"] == "restart")
    print(f"steps run: {len(losses)} (incl. replay after {restarts} "
          f"restart)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
