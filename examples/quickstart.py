"""Quickstart: generate a CGRA interconnect with the Canal eDSL, place and
route an application on it, generate the bitstream, and emulate the fabric.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.bitstream import BitstreamCodec
from repro.core.edsl import create_uniform_interconnect
from repro.core.lowering import compile_interconnect
from repro.core.pnr import place_and_route
from repro.core.pnr.app import app_pointwise
from repro.core.pnr.packing import pack
from repro.fabric import AppEmulator


def main():
    # 1. the paper's Fig. 4 helper: a uniform Wilton interconnect
    ic = create_uniform_interconnect(width=6, height=6, num_tracks=4,
                                     sb_type="wilton", io_ring=True,
                                     reg_density=1.0)
    print(f"interconnect: {ic.num_nodes()} IR nodes, "
          f"{ic.num_edges()} edges")

    # 2. lower to the functional fabric (static backend)
    fabric = compile_interconnect(ic)
    print(f"fabric: {fabric.num_config} config registers")

    # 3. an application: out = ((in + 1) + 2) + 3
    app = app_pointwise(3)
    packed = pack(app)
    result = place_and_route(ic, app, alphas=(2.0,), sa_steps=60)
    assert result.success, result.error
    print(f"PnR: crit path {result.timing['critical_path_ns']:.2f} ns, "
          f"wirelength {result.wirelength}, "
          f"{result.route_iterations} routing iterations")

    # 4. bitstream
    codec = BitstreamCodec(fabric)
    words = codec.words_for_route(result.route_edges())
    print(f"bitstream: {len(words)} config words")

    # 5. emulate
    emu = AppEmulator.from_pnr(fabric, packed, result)
    T = 12
    x = np.arange(50, 50 + T).astype(np.int32)
    outs = emu.run({result.placement["in0"]: x}, T)
    y = outs[result.placement["out0"]]
    lat = np.nonzero(y)[0][0]
    print(f"emulation: in={x[:6]} -> out={y[lat:lat + 6]} "
          f"(latency {lat} cycles)")
    assert list(y[lat:lat + 6]) == list(x[:6] + 6)
    print("OK")


if __name__ == "__main__":
    main()
