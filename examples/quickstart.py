"""Quickstart: the Canal front door in five steps — describe an
interconnect as a frozen spec, compile it through the pass pipeline,
place and route an application, generate the bitstream, and emulate.

    PYTHONPATH=src python examples/quickstart.py

(The old imperative entry point, ``create_uniform_interconnect``, still
works but is deprecated; it is a shim over this same pipeline.)
"""
import numpy as np

import canal
from repro.core.pnr.app import app_pointwise


def main():
    # 1. declare the design point: frozen, hashable, JSON-round-trippable
    spec = canal.InterconnectSpec(width=6, height=6, num_tracks=4,
                                  sb_type="wilton", io_ring=True,
                                  reg_density=1.0)
    print(f"spec: digest {spec.digest()[:16]}")

    # 2. compile: named IR passes -> CompiledFabric handle
    fab = canal.compile(spec)
    ic = fab.interconnect
    print(f"interconnect: {ic.num_nodes()} IR nodes, {ic.num_edges()} "
          f"edges via passes "
          f"{[e['pass'] for e in fab.pass_log]}")
    print(f"fabric: {fab.fabric().num_config} config registers, "
          f"area {fab.area()['sb_area']:.0f} um2 (SB)")

    # 3. an application: out = ((in + 1) + 2) + 3
    app = app_pointwise(3)
    result = fab.place_and_route(app, alphas=(2.0,), sa_steps=60)
    assert result.success, result.error
    print(f"PnR: crit path {result.timing['critical_path_ns']:.2f} ns, "
          f"wirelength {result.wirelength}, "
          f"{result.route_iterations} routing iterations "
          f"(router: {result.route_strategy})")

    # 4. bitstream
    words = fab.bitstream(result)
    print(f"bitstream: {len(words)} config words")

    # 5. emulate (inputs keyed by app instance name or IO tile coord)
    T = 12
    x = np.arange(50, 50 + T).astype(np.int32)
    outs = fab.emulate(result, {"in0": x}, cycles=T)
    y = outs[result.placement["out0"]]
    lat = np.nonzero(y)[0][0]
    print(f"emulation: in={x[:6]} -> out={y[lat:lat + 6]} "
          f"(latency {lat} cycles)")
    assert list(y[lat:lat + 6]) == list(x[:6] + 6)
    print("OK")


if __name__ == "__main__":
    main()
