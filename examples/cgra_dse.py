"""Design-space exploration example (paper §4.2 in miniature): sweep
switch-box topology and track count through the persistent, store-backed
serving front end (``canal.serve``), report area + routability +
critical path, and run the same Canal router on a TPU-pod traffic
pattern (the beyond-paper ICI integration).

Re-run it: the second invocation serves every design point from the
on-disk result store (`.canal_store` / ``$CANAL_RESULT_STORE``) by spec
digest — zero PnR recomputation.

    PYTHONPATH=src python examples/cgra_dse.py
"""
import numpy as np

import canal
from repro.core.dse import sweep_sb_topology
from repro.core.ici import pod_collective_model, route_traffic_canal
from repro.core.pnr.app import app_butterfly


def main():
    # one serving front end for the whole session: coalescing queries
    # over the persistent result store, misses batched through a shared
    # SweepExecutor. The annealing budget (sa_steps) is a spec field now.
    svc = canal.serve(apps={"butterfly3": lambda: app_butterfly(3)})

    print("== topology DSE (Wilton vs Disjoint, Fc=0.5) ==")
    recs = sweep_sb_topology(
        (canal.SwitchBoxType.WILTON, canal.SwitchBoxType.DISJOINT),
        num_tracks=4, track_fc=0.5, executor=svc.executor)
    for r in recs:
        print(f"  {r['topology']:9s} routed {r['n_routed']}/{r['n_apps']} "
              f"sb_area={r['sb_area']:.0f}um2")

    print("== track-count DSE (spec grid served by digest) ==")
    base = canal.InterconnectSpec(width=8, height=8, io_ring=True,
                                  reg_density=1.0, cb_track_fc=0.5,
                                  sb_track_fc=0.5, sa_steps=40)
    grid = canal.spec_grid(base, {"num_tracks": (2, 4, 6)})
    recs = svc.query([spec for spec, _ in grid])
    for (spec, extra), r in zip(grid, recs):
        ok = [a for a in r["apps"].values() if a["success"]]
        crit = (sum(a["critical_path_ns"] for a in ok) / len(ok)
                if ok else float("nan"))
        print(f"  tracks={extra['num_tracks']} sb={r['sb_area']:.0f}um2 "
              f"cb={r['cb_area']:.0f}um2 routed={len(ok)} "
              f"crit={crit:.2f}ns spec={r['spec_digest'][:10]}")

    # querying the same grid again is pure store/coalesce traffic
    svc.query([spec for spec, _ in grid])
    st = svc.stats()
    print(f"  serve stats: hits={st['hits']} misses={st['misses']} "
          f"hit_rate={st['hit_rate']:.2f} "
          f"warm-query avg {st['latency_avg_s'] * 1e3:.1f} ms "
          f"(store: {st['store']['records']} records on disk)")

    print("== search-driven DSE (greedy selector vs full grid) ==")
    # instead of enumerating the grid, let a selector walk it: the
    # greedy hill-climber starts at the base point, explores axis
    # neighbors, and stops at the budget — typically touching fewer
    # points than the grid while landing on the same Pareto frontier.
    # Evaluation goes through the same store-backed executor, so
    # re-running the search is zero-PnR.
    res = svc.recommend(base, {"num_tracks": (2, 3, 4, 5, 6)},
                        objective="area",
                        constraints={"min_routability": 1.0},
                        budget=4, batch_size=2)
    for p in res["frontier"]:
        m = p["metrics"]
        print(f"  frontier: tracks={p['spec']['num_tracks']} "
              f"area={m['area']:.0f}um2 crit={m['critical_path_ns']:.2f}ns "
              f"routability={m['routability']:.2f}")
    best = res["best"]
    label = (f"tracks={best['spec']['num_tracks']}" if best
             else "none feasible")
    print(f"  best (min area, fully routable): {label} "
          f"after {res['stats']['evaluated']} evaluations "
          f"(grid is {res['stats']['space_size']} points; "
          f"{res['stats']['executor']['pnr_computations']} new PnR)")

    print("== pod-fabric DSE (Canal router on the ICI torus) ==")
    rng = np.random.default_rng(0)
    flows = [((int(rng.integers(0, 4)), int(rng.integers(0, 4))),
              (int(rng.integers(0, 4)), int(rng.integers(0, 4))))
             for _ in range(10)]
    flows = [(s, d) for s, d in flows if s != d]
    result, usage = route_traffic_canal(4, 4, flows)
    print(f"  {len(result.nets)} flows routed in "
          f"{result.iterations} PathFinder iterations, "
          f"max transit usage {usage.max()}")
    out = pod_collective_model({"all-reduce": 1e9, "all-gather": 4e8},
                               {"data": 16, "model": 16})
    print(f"  collective model: congestion x{out['congestion_factor']:.2f}"
          f" -> {out['collective_time_s'] * 1e3:.2f} ms "
          f"(naive {out['naive_time_s'] * 1e3:.2f} ms)")
    print("OK")


if __name__ == "__main__":
    main()
