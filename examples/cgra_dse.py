"""Design-space exploration example (paper §4.2 in miniature): sweep
switch-box topology and track count, report area + routability + critical
path, and run the same Canal router on a TPU-pod traffic pattern
(the beyond-paper ICI integration).

    PYTHONPATH=src python examples/cgra_dse.py
"""
import numpy as np

import canal
from repro.core.dse import SweepExecutor, sweep_sb_topology
from repro.core.ici import pod_collective_model, route_traffic_canal
from repro.core.pnr.app import app_butterfly


def main():
    print("== topology DSE (Wilton vs Disjoint, Fc=0.5) ==")
    recs = sweep_sb_topology(
        (canal.SwitchBoxType.WILTON, canal.SwitchBoxType.DISJOINT),
        apps={"butterfly3": lambda: app_butterfly(3)},
        num_tracks=4, sa_steps=40, track_fc=0.5)
    for r in recs:
        print(f"  {r['topology']:9s} routed {r['n_routed']}/{r['n_apps']} "
              f"sb_area={r['sb_area']:.0f}um2")

    print("== track-count DSE (declarative spec grid) ==")
    base = canal.InterconnectSpec(width=8, height=8, io_ring=True,
                                  reg_density=1.0, cb_track_fc=0.5,
                                  sb_track_fc=0.5)
    ex = SweepExecutor(apps={"butterfly3": lambda: app_butterfly(3)},
                       sa_steps=40)
    recs = ex.run_points(canal.spec_grid(base, {"num_tracks": (2, 4, 6)}))
    for r in recs:
        ok = [a for a in r["apps"].values() if a["success"]]
        crit = (sum(a["critical_path_ns"] for a in ok) / len(ok)
                if ok else float("nan"))
        print(f"  tracks={r['num_tracks']} sb={r['sb_area']:.0f}um2 "
              f"cb={r['cb_area']:.0f}um2 routed={len(ok)} "
              f"crit={crit:.2f}ns spec={r['spec_digest'][:10]}")

    print("== pod-fabric DSE (Canal router on the ICI torus) ==")
    rng = np.random.default_rng(0)
    flows = [((int(rng.integers(0, 4)), int(rng.integers(0, 4))),
              (int(rng.integers(0, 4)), int(rng.integers(0, 4))))
             for _ in range(10)]
    flows = [(s, d) for s, d in flows if s != d]
    result, usage = route_traffic_canal(4, 4, flows)
    print(f"  {len(result.nets)} flows routed in "
          f"{result.iterations} PathFinder iterations, "
          f"max transit usage {usage.max()}")
    out = pod_collective_model({"all-reduce": 1e9, "all-gather": 4e8},
                               {"data": 16, "model": 16})
    print(f"  collective model: congestion x{out['congestion_factor']:.2f}"
          f" -> {out['collective_time_s'] * 1e3:.2f} ms "
          f"(naive {out['naive_time_s'] * 1e3:.2f} ms)")
    print("OK")


if __name__ == "__main__":
    main()
