"""Serving example: batched greedy decoding with the slot-based engine
(prefill + KV-cache decode), on a smoke-scale model.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

import jax

from repro.configs import get_smoke
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    cfg = get_smoke("tinyllama_1_1b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_size=4, max_seq=96)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, cfg.vocab_size - 1, size=n)
               .astype(np.int32) for n in (5, 9, 7, 3, 6)]
    outs = engine.generate(prompts, max_new_tokens=12)
    for i, (p, o) in enumerate(zip(prompts, outs)):
        print(f"req{i}: prompt={list(p)} -> generated={o}")
    assert all(len(o) >= 1 for o in outs)
    # determinism: same batch -> same greedy outputs
    again = engine.generate(prompts, max_new_tokens=12)
    assert again == outs, "greedy decode must be deterministic"
    print("OK")


if __name__ == "__main__":
    main()
